"""Checkpoint serialization: pytree -> flat npz + msgpack manifest.

No orbax/tensorstore in this container, so we implement a compact
self-describing format:

  <dir>/manifest.msgpack   -- treedef paths, shapes, dtypes, metadata
  <dir>/arrays.npz         -- one entry per leaf (key = joined path)

Leaves are gathered to host numpy. On multi-host deployments each process
would write its addressable shards (path + shard index); the single-process
container writes full arrays, but the manifest already records logical
shapes so `elastic.py` can re-shard on restore onto a different mesh.
"""
from __future__ import annotations

import io
import os
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np

SEP = "/"


def _flatten_with_paths(tree, prefix=()):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.extend(_flatten_with_paths(tree[k], prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_flatten_with_paths(v, prefix + (str(i),)))
    elif tree is None:
        pass
    else:
        out.append((SEP.join(prefix), tree))
    return out


def save_tree(path: str, tree: Any, metadata: Dict[str, Any] | None = None
              ) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"leaves": [], "metadata": metadata or {}}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(path, "arrays.npz"), **arrays)


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def load_tree(path: str, like: Any | None = None) -> Tuple[Any, dict]:
    """Returns (tree, metadata). If `like` is given, arrays are placed into
    its structure (and must match shapes); otherwise a nested dict keyed by
    path segments is returned."""
    manifest = load_manifest(path)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat = {e["key"]: npz[e["key"]] for e in manifest["leaves"]}

    if like is None:
        tree: dict = {}
        for key, arr in flat.items():
            node = tree
            parts = key.split(SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return tree, manifest["metadata"]

    like_leaves = _flatten_with_paths(like)
    lookup = dict(like_leaves)
    missing = [k for k, _ in like_leaves if k not in flat]
    extra = [k for k in flat if k not in lookup]
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing[:5]} "
                         f"extra={extra[:5]}")

    def build(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: build(v, prefix + (str(k),))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [build(v, prefix + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(vals) if not hasattr(tree, "_fields") \
                else type(tree)(*vals)
        if tree is None:
            return None
        return flat[SEP.join(prefix)]

    return build(like), manifest["metadata"]
