"""Checkpoint serialization: pytree -> flat npz + msgpack manifest.

No orbax/tensorstore in this container, so we implement a compact
self-describing format:

  <dir>/manifest.msgpack   -- treedef paths, shapes, dtypes, crc32s, metadata
  <dir>/arrays.npz         -- one entry per leaf (key = joined path)

Leaves are gathered to host numpy. On multi-host deployments each process
would write its addressable shards (path + shard index); the single-process
container writes full arrays, but the manifest already records logical
shapes so `elastic.py` can re-shard on restore onto a different mesh.

Integrity contract (ISSUE-7):

* every leaf's crc32 is recorded in the manifest, and ``load_tree`` /
  ``verify_tree`` recompute it on read -- a bit-flipped or truncated
  checkpoint raises :class:`CheckpointCorruptError` instead of silently
  resuming from garbage;
* the arrays file is written leaf-by-leaf and the manifest LAST, with a
  ``fault`` hook fired between every write -- the chaos harness
  (``distributed/chaos.py``) kills saves at arbitrary points and the
  property tests assert that no interleaving ever produces a directory
  that verifies (torn saves are always detectably incomplete; the
  manager's tmp-dir + rename layer then keeps them out of ``step_N``).
"""
from __future__ import annotations

import io
import os
import zipfile
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """The on-disk checkpoint is unreadable or fails checksum validation."""


def _flatten_with_paths(tree, prefix=()):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.extend(_flatten_with_paths(tree[k], prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_flatten_with_paths(v, prefix + (str(i),)))
    elif tree is None:
        pass
    else:
        out.append((SEP.join(prefix), tree))
    return out


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_tree(path: str, tree: Any, metadata: Dict[str, Any] | None = None,
              fault: Optional[Callable[[str], None]] = None) -> None:
    """Write ``tree`` under ``path``.  ``fault(point)`` (when given) is
    called at every write boundary -- ``begin``, ``leaf:<key>`` before each
    array, ``central_directory`` before the npz index, ``manifest`` before
    the manifest, ``end`` -- and may raise to simulate a writer killed at
    that point.  A save killed anywhere leaves a directory that
    ``verify_tree`` rejects (the manifest is written last), never a
    silently-truncated tree."""
    fire = fault if fault is not None else (lambda point: None)
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"leaves": [], "metadata": metadata or {}}
    host = []
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        host.append((key, arr))
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "crc": _crc(arr)})
    fire("begin")
    # arrays.npz is written entry-by-entry (npz IS a zip of .npy members)
    # so a killed writer leaves a partial file without a central directory
    # -- np.load refuses it, verify_tree flags it.  The plain open (no
    # context manager around the ZipFile) is deliberate: an exception must
    # not flush the index and "complete" a torn file on unwind.
    f = open(os.path.join(path, "arrays.npz"), "wb")
    zf = zipfile.ZipFile(f, "w", allowZip64=True)
    try:
        for key, arr in host:
            fire(f"leaf:{key}")
            buf = io.BytesIO()
            np.save(buf, arr)
            zf.writestr(key + ".npy", buf.getvalue())
            f.flush()
        fire("central_directory")
        zf.close()
    except BaseException:
        zf.fp = None   # detach: GC must not flush the index of a torn file
        raise
    finally:
        f.close()
    fire("manifest")
    with open(os.path.join(path, "manifest.msgpack"), "wb") as mf:
        mf.write(msgpack.packb(manifest))
    fire("end")


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())


def _load_flat(path: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """(manifest, {key: array}) with per-leaf checksum validation.
    Raises CheckpointCorruptError on any unreadable or mismatching leaf."""
    try:
        manifest = load_manifest(path)
    except (OSError, ValueError, msgpack.exceptions.UnpackException) as e:
        raise CheckpointCorruptError(
            f"{path}: manifest unreadable ({e})") from e
    try:
        npz = np.load(os.path.join(path, "arrays.npz"))
        flat = {}
        for e in manifest["leaves"]:
            key = e["key"]
            if key not in npz:
                raise CheckpointCorruptError(
                    f"{path}: leaf {key!r} in manifest but not in arrays")
            arr = npz[key]
            if list(arr.shape) != e["shape"] or str(arr.dtype) != e["dtype"]:
                raise CheckpointCorruptError(
                    f"{path}: leaf {key!r} is {arr.dtype}{arr.shape}, "
                    f"manifest says {e['dtype']}{e['shape']}")
            # manifests written before ISSUE-7 carry no crc: accept them
            # (legacy checkpoints stay restorable) but anything written by
            # this code is always checksum-verified
            if "crc" in e and _crc(arr) != e["crc"]:
                raise CheckpointCorruptError(
                    f"{path}: leaf {key!r} fails its checksum "
                    f"(stored {e['crc']}, computed {_crc(arr)})")
            flat[key] = arr
    except CheckpointCorruptError:
        raise
    except (OSError, ValueError, KeyError, zipfile.BadZipFile, EOFError) as e:
        raise CheckpointCorruptError(
            f"{path}: arrays unreadable ({e})") from e
    return manifest, flat


def verify_tree(path: str) -> dict:
    """Validate a checkpoint directory end-to-end (manifest readable, every
    leaf present, shapes/dtypes/checksums match).  Returns the metadata;
    raises :class:`CheckpointCorruptError` on the first violation."""
    manifest, _ = _load_flat(path)
    return manifest["metadata"]


def load_tree(path: str, like: Any | None = None) -> Tuple[Any, dict]:
    """Returns (tree, metadata). If `like` is given, arrays are placed into
    its structure (and must match shapes); otherwise a nested dict keyed by
    path segments is returned.  Every leaf is checksum-verified on read."""
    manifest, flat = _load_flat(path)

    if like is None:
        tree: dict = {}
        for key, arr in flat.items():
            node = tree
            parts = key.split(SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return tree, manifest["metadata"]

    like_leaves = _flatten_with_paths(like)
    lookup = dict(like_leaves)
    missing = [k for k, _ in like_leaves if k not in flat]
    extra = [k for k in flat if k not in lookup]
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing[:5]} "
                         f"extra={extra[:5]}")

    def build(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: build(v, prefix + (str(k),))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [build(v, prefix + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(vals) if not hasattr(tree, "_fields") \
                else type(tree)(*vals)
        if tree is None:
            return None
        return flat[SEP.join(prefix)]

    return build(like), manifest["metadata"]
