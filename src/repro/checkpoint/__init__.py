from repro.checkpoint.elastic import reshard_tree
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.serialization import load_tree, save_tree

__all__ = ["CheckpointManager", "load_tree", "save_tree", "reshard_tree"]
