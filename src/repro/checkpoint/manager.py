"""Fault-tolerant checkpoint manager.

  * atomic: write to <dir>/tmp_step_N then os.rename -> step_N (a crashed
    writer never corrupts the latest checkpoint)
  * keep-k garbage collection
  * async: saves run on a background thread (the train loop never blocks on
    I/O); `wait()` joins before exit / preemption flush
  * latest_step() / restore() drive auto-resume in the train loop
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import serialization as ser

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- query --
    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -------------------------------------------------------------- save --
    def _save_sync(self, step: int, tree: Any, metadata: Dict) -> None:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = os.path.join(self.directory, f"tmp_step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        ser.save_tree(tmp, tree, metadata={**metadata, "step": step})
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None
             ) -> None:
        self.wait()
        meta = dict(metadata or {})
        if self.async_save:
            # device_get on the caller thread (cheap for PEFT state), I/O on
            # the background thread
            import jax
            host_tree = jax.tree_util.tree_map(
                lambda x: jax.device_get(x) if hasattr(x, "shape") else x,
                tree)
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, meta),
                daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, tree, meta)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore --
    def restore(self, step: Optional[int] = None, like: Any = None
                ) -> Tuple[Any, Dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        return ser.load_tree(path, like=like)
