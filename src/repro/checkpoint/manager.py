"""Fault-tolerant checkpoint manager.

  * atomic: write to <dir>/tmp_step_N then os.rename -> step_N (a crashed
    writer never corrupts the latest checkpoint)
  * keep-k garbage collection
  * async: saves run on a background thread (the train loop never blocks on
    I/O); `wait()` joins before exit / preemption flush and RE-RAISES any
    exception the writer thread hit (a crashed async save is never silent)
  * latest_step() / restore() drive auto-resume in the train loop
  * resilience (ISSUE-7): stale ``tmp_step_*`` directories left by a
    crashed writer are swept on init; ``restore()`` checksum-verifies and,
    when no explicit step is requested, falls back to the newest VALID
    step if the latest is corrupt or torn; ``arm_fault()`` lets the chaos
    harness kill the next save mid-write.
"""
from __future__ import annotations

import logging
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro import obs
from repro.checkpoint import serialization as ser
from repro.checkpoint.serialization import CheckpointCorruptError

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^tmp_step_(\d+)$")

log = logging.getLogger("repro.checkpoint")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._fault: Optional[Callable[[str], None]] = None
        os.makedirs(directory, exist_ok=True)
        self.swept = self._sweep_tmp()

    def _sweep_tmp(self) -> int:
        """Remove ``tmp_step_*`` leftovers from a writer that died mid-save
        (the rename to ``step_N`` never happened, so they are invisible to
        ``steps()`` but would accumulate forever)."""
        swept = 0
        for name in os.listdir(self.directory):
            if _TMP_RE.match(name):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)
                swept += 1
        if swept:
            log.warning("swept %d stale tmp_step_* dir(s) from %s "
                        "(crashed writer)", swept, self.directory)
        return swept

    # ------------------------------------------------------------- query --
    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def step_path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step}")

    def verify(self, step: int) -> bool:
        """True when ``step_<step>`` passes full checksum validation."""
        try:
            ser.verify_tree(self.step_path(step))
            return True
        except CheckpointCorruptError:
            return False

    # -------------------------------------------------------------- save --
    def arm_fault(self, fault: Optional[Callable[[str], None]]) -> None:
        """Install a one-shot fault hook for the NEXT save (chaos harness:
        kill the writer at a chosen point inside ``save_tree``)."""
        self._fault = fault

    def _save_sync(self, step: int, tree: Any, metadata: Dict) -> None:
        t0 = time.perf_counter()
        final = os.path.join(self.directory, f"step_{step}")
        tmp = os.path.join(self.directory, f"tmp_step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        fault, self._fault = self._fault, None
        ser.save_tree(tmp, tree, metadata={**metadata, "step": step},
                      fault=fault)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        # observed from the writer thread on async saves -- the histogram
        # is what the I/O costs, not what the train loop blocked on
        obs.metric("train/checkpoint_save_seconds").observe(
            time.perf_counter() - t0)
        obs.metric("train/checkpoint_saves_total").inc()

    def _save_thread(self, step: int, tree: Any, metadata: Dict) -> None:
        try:
            self._save_sync(step, tree, metadata)
        except BaseException as e:                          # noqa: BLE001
            self._error = e

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None
             ) -> None:
        self.wait()
        meta = dict(metadata or {})
        if self.async_save:
            # device_get on the caller thread (cheap for PEFT state), I/O on
            # the background thread
            import jax
            host_tree = jax.tree_util.tree_map(
                lambda x: jax.device_get(x) if hasattr(x, "shape") else x,
                tree)
            self._thread = threading.Thread(
                target=self._save_thread, args=(step, host_tree, meta),
                daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, tree, meta)

    def wait(self) -> None:
        """Join the in-flight async save; re-raise its exception if the
        writer thread died (a torn tmp dir is left behind for init-time
        sweeping -- exactly what a process crash would leave)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore --
    def restore(self, step: Optional[int] = None, like: Any = None
                ) -> Tuple[Any, Dict]:
        """Load a checkpoint (checksum-verified).

        With an explicit ``step``, corruption raises
        :class:`CheckpointCorruptError` -- the caller asked for THAT step.
        With ``step=None``, walks steps newest -> oldest and restores the
        newest VALID one, logging each corrupt step it skips; raises only
        when every step on disk is corrupt."""
        t0 = time.perf_counter()

        def done(result):
            obs.metric("train/checkpoint_restore_seconds").observe(
                time.perf_counter() - t0)
            obs.metric("train/checkpoint_restores_total").inc()
            return result

        if step is not None:
            return done(ser.load_tree(self.step_path(step), like=like))
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_err: Optional[CheckpointCorruptError] = None
        for s in reversed(steps):
            try:
                return done(ser.load_tree(self.step_path(s), like=like))
            except CheckpointCorruptError as e:
                log.warning("checkpoint step_%d is corrupt (%s); falling "
                            "back to the previous step", s, e)
                last_err = e
        raise CheckpointCorruptError(
            f"every checkpoint in {self.directory} is corrupt "
            f"(steps {steps})") from last_err
