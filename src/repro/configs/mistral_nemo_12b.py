"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 -- 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407; hf].
head_dim=128 (q dim 4096 != d_model, supported natively)."""
from repro.config.base import ModelConfig

FAMILY = "dense"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense", num_layers=40, d_model=5120,
        num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=131072, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-smoke", family="dense", num_layers=2,
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=48, d_ff=256,
        vocab_size=512, rope_theta=1e4)
