"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
-- llama-arch GQA [arXiv:2403.04652; hf]. 56 heads are TP-padded to 64 on
the 16-wide model axis (exact numerics: zero o-proj columns)."""
from repro.config.base import ModelConfig

FAMILY = "dense"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", num_layers=60, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=20480,
        vocab_size=64000, rope_theta=5_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    # 7 heads: deliberately not a power of two so the padding path is
    # exercised in the smoke tests as well
    return ModelConfig(
        name="yi-34b-smoke", family="dense", num_layers=2, d_model=112,
        num_heads=7, num_kv_heads=1, head_dim=16, d_ff=256, vocab_size=500,
        rope_theta=1e4)
