"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 -- llama-arch, code [arXiv:2405.04324; hf]."""
from repro.config.base import ModelConfig

FAMILY = "dense"
LONG_CONTEXT_OK = False   # pure full attention: long_500k skipped


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense", num_layers=36, d_model=4096,
        num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=49152, rope_theta=10_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        rope_theta=1e4)
