"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16, i.e. MHA) d_ff=5120
vocab=504 -- encoder-only, same arch as wav2vec2 [arXiv:2106.07447;
unverified].

Per task spec the conv feature extractor is a STUB: input_specs provide
precomputed 512-dim frames. Encoder-only => decode_32k / long_500k skipped.
RoPE stands in for HuBERT's conv positional embedding (frontend stub);
plain (non-GLU) GELU MLP matches wav2vec2."""
from repro.config.base import ModelConfig

FAMILY = "encoder"
LONG_CONTEXT_OK = False
DECODE_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder", num_layers=48, d_model=1280,
        num_heads=16, num_kv_heads=16, head_dim=80, d_ff=5120,
        vocab_size=504, is_encoder=True, causal=False, glu=False,
        act="gelu", frontend="audio_frames", frontend_dim=512,
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="encoder", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=32, is_encoder=True, causal=False, glu=False, act="gelu",
        frontend="audio_frames", frontend_dim=24, rope_theta=1e4)
