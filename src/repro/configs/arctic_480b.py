"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

128 experts -> expert-parallel layout (experts sharded over `data`);
dense-residual FFN runs in parallel with the MoE branch every layer.
56 heads TP-padded to 64. Experts are frozen (not adapter targets) --
DESIGN.md §Arch-applicability."""
from repro.config.base import ModelConfig

FAMILY = "moe"
LONG_CONTEXT_OK = False   # full attention


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=4864,
        vocab_size=32000, num_experts=128, top_k=2, moe_period=1,
        dense_residual=True, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=512,
        num_experts=8, top_k=2, moe_period=1, dense_residual=True,
        rope_theta=1e4)
