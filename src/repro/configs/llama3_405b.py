"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 -- GQA 128k vocab [arXiv:2407.21783; unverified]."""
from repro.config.base import ModelConfig

FAMILY = "dense"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense", num_layers=126, d_model=16384,
        num_heads=128, num_kv_heads=8, head_dim=128, d_ff=53248,
        vocab_size=128256, rope_theta=500_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense", num_layers=3, d_model=128,
        num_heads=8, num_kv_heads=2, head_dim=16, d_ff=384, vocab_size=512,
        rope_theta=5e5)
