"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 -- Mamba+attn 1:7 interleave
[arXiv:2403.19887; hf].

Period-8 scan groups (attention at offset 3, mamba elsewhere; MoE on odd
layers per Jamba's every-other-layer placement). ssm_state=16 matches
Jamba's d_state; the SSM core is our SSD (mamba2) implementation --
documented adaptation. long_500k runs: mamba state is O(1), the single
attention-in-8 keeps a KV cache."""
from repro.config.base import ModelConfig

FAMILY = "hybrid"
LONG_CONTEXT_OK = True


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
        vocab_size=65536, num_experts=16, top_k=2, moe_period=2,
        moe_offset=1, attn_period=8, attn_offset=3, scan_block=8,
        ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        use_rope=False,   # Jamba uses no positional embedding in attn
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke", family="hybrid", num_layers=8,
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, num_experts=4, top_k=2, moe_period=2, moe_offset=1,
        attn_period=4, attn_offset=3, scan_block=4, ssm_state=16,
        ssm_headdim=16, ssm_expand=2, ssm_chunk=8, use_rope=False)
