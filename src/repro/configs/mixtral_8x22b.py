"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088; hf].

Sliding window => long_500k decode runs with a window-capped ring KV cache.
8 experts do not divide the 16-wide axes -> TP-within-expert MoE layout."""
from repro.config.base import ModelConfig

FAMILY = "moe"
LONG_CONTEXT_OK = True    # SWA bounds the KV cache


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
        num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384,
        vocab_size=32768, num_experts=8, top_k=2, moe_period=1,
        sliding_window=4096, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        num_experts=4, top_k=2, moe_period=1, sliding_window=8,
        rope_theta=1e4)
