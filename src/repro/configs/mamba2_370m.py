"""mamba2-370m [ssm]: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 -- SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: every layer is an SSD block, no MLP (d_ff=0). long_500k
runs with an O(1) recurrent decode state. OFT adapts in_proj/out_proj."""
from repro.config.base import ModelConfig

FAMILY = "ssm"
LONG_CONTEXT_OK = True


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
        num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ssm_ngroups=1, use_rope=False, tie_embeddings=True,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke", family="ssm", num_layers=2, d_model=64,
        num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=512,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=8,
        use_rope=False, tie_embeddings=True)
