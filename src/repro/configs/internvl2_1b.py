"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 -- InternViT + InternLM2(Qwen2-0.5B) backbone
[arXiv:2404.16821; hf].

Per task spec the modality frontend is a STUB: input_specs provide
precomputed patch embeddings (256 tokens x 1024 = InternViT-300M output
after pixel-shuffle) projected into the LM. 14 heads TP-padded to 16."""
from repro.config.base import ModelConfig

FAMILY = "vlm"
LONG_CONTEXT_OK = False


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm", num_layers=24, d_model=896,
        num_heads=14, num_kv_heads=2, head_dim=64, d_ff=4864,
        vocab_size=151655, frontend="vision_patches", frontend_dim=1024,
        num_frontend_tokens=256, tie_embeddings=True,
        rope_theta=1_000_000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm", num_layers=2, d_model=112,
        num_heads=7, num_kv_heads=1, head_dim=16, d_ff=256, vocab_size=500,
        frontend="vision_patches", frontend_dim=32, num_frontend_tokens=4,
        tie_embeddings=True, rope_theta=1e4)
