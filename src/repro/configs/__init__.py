"""Architecture registry: --arch <id> resolves here.

Each module exports config() (the exact public-literature config), smoke()
(a reduced same-family config for CPU tests), FAMILY, and capability flags
used by the dry-run cell matrix (LONG_CONTEXT_OK, DECODE_OK)."""
from __future__ import annotations

from typing import Dict, Optional

from repro.config.base import SHAPES, ModelConfig
from repro.configs import (arctic_480b, granite_8b, hubert_xlarge,
                           internvl2_1b, jamba_v01_52b, llama3_405b,
                           mamba2_370m, mistral_nemo_12b, mixtral_8x22b,
                           paper_models, yi_34b)

REGISTRY = {
    "granite-8b": granite_8b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "llama3-405b": llama3_405b,
    "yi-34b": yi_34b,
    "mixtral-8x22b": mixtral_8x22b,
    "arctic-480b": arctic_480b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "internvl2-1b": internvl2_1b,
    "mamba2-370m": mamba2_370m,
    "hubert-xlarge": hubert_xlarge,
    # the paper's own models (bench targets)
    "qwen2.5-7b": paper_models,
}

ASSIGNED = [k for k in REGISTRY if k != "qwen2.5-7b"]


def get_config(name: str) -> ModelConfig:
    return REGISTRY[name].config()


def get_smoke(name: str) -> ModelConfig:
    return REGISTRY[name].smoke()


def cell_skip_reason(name: str, shape: str) -> Optional[str]:
    """None = the (arch x shape) cell runs; else the documented skip reason
    (DESIGN.md §5)."""
    mod = REGISTRY[name]
    kind = SHAPES[shape].kind
    if kind == "decode" and not getattr(mod, "DECODE_OK", True):
        return "encoder-only: no decode step"
    if shape == "long_500k" and not getattr(mod, "LONG_CONTEXT_OK", False):
        return "pure full attention: 500k decode cache infeasible " \
               "(needs sub-quadratic attention)"
    return None


def cells(shapes=None):
    """All (arch, shape, skip_reason) cells of the assignment matrix."""
    shapes = shapes or list(SHAPES)
    out = []
    for arch in ASSIGNED:
        for shape in shapes:
            out.append((arch, shape, cell_skip_reason(arch, shape)))
    return out
