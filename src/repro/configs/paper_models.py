"""The paper's own models (benchmark/fidelity targets, not assigned archs):
Qwen2.5-7B (Fig 1 scalability runs) and Llama-2-7B/13B (Table 4
parameter-count fidelity: LoRA r=16 -> 39.98M, OFTv2 b=32 -> 17.65M)."""
from repro.config.base import ModelConfig

FAMILY = "dense"
LONG_CONTEXT_OK = False


def qwen25_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-7b", family="dense", num_layers=28, d_model=3584,
        num_heads=28, num_kv_heads=4, head_dim=128, d_ff=18944,
        vocab_size=152064, rope_theta=1_000_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def llama2_7b() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
        vocab_size=32000, rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def llama2_13b() -> ModelConfig:
    return ModelConfig(
        name="llama2-13b", family="dense", num_layers=40, d_model=5120,
        num_heads=40, num_kv_heads=40, head_dim=128, d_ff=13824,
        vocab_size=32000, rope_theta=10_000.0,
        dtype="bfloat16", param_dtype="bfloat16")


config = qwen25_7b


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-7b-smoke", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        rope_theta=1e4)
