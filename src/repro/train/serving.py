"""Serving utilities: prefill -> decode continuation, cache padding, and a
batched greedy/sampling generation loop (the paper's "inference" side --
adapters stay unmerged, exactly how the paper evaluates QOFT/QLoRA).

Multi-tenant serving (many adapters, one frozen base, mixed batches) lives
in ``repro.serving``; it builds on the same primitives here (``pad_caches``,
the per-model jit cache)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.model import Model


def pad_caches(model: Model, caches: dict, s_max: int) -> dict:
    """Grow prefill caches (seq dim == prompt length) to s_max decode slots.

    Attention caches get zero-padded k/v and pos=-1 (invalid) tail; SSM
    states are seq-free and pass through. SWA ring caches (already capped at
    the window) pass through too."""
    cfg = model.cfg

    def pad_entry(p, entry):
        if tfm.layer_kind(cfg, p) != "attn":
            return entry
        cur = entry["k"].shape[2]          # (n_groups, B, S, KV, hd)
        if cur >= s_max or (0 < cfg.sliding_window <= cur):
            return entry
        padn = s_max - cur
        k = jnp.pad(entry["k"], ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(entry["v"], ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        pos = jnp.pad(entry["pos"], ((0, 0), (0, 0), (0, padn)),
                      constant_values=-1)
        return {"k": k, "v": v, "pos": pos}

    return {key: pad_entry(int(key.split("_")[1]), val)
            for key, val in caches.items()}


def model_jit_fn(model: Model, name: str, fn, jit: bool = True):
    """Per-model-instance jit cache: the compiled fn survives across
    ``generate`` calls (and across the N sequential runs of the serving
    benchmark's baseline) instead of retracing per call.  ``jit=False`` is
    the debugging escape hatch -- the raw fn, eager, with real stack
    traces."""
    if not jit:
        return fn
    cache = getattr(model, "_jit_cache", None)
    if cache is None:
        cache = {}
        model._jit_cache = cache
    if name not in cache:
        cache[name] = jax.jit(fn)
    return cache[name]


def prefill_fn(model: Model, jit: bool = True):
    """(params, batch) -> (logits, caches), jitted per model instance."""
    return model_jit_fn(model, "prefill",
                        lambda p, b: model.prefill(p, b), jit=jit)


def decode_fn(model: Model, jit: bool = True):
    """(params, batch) -> (logits, new_caches), jitted per model instance.
    Per-token dispatch overhead -- not math -- dominates small-model
    decode, so the step is compiled once and reused across all steps,
    generate() calls, and serving-engine ticks."""
    return model_jit_fn(model, "decode",
                        lambda p, b: model.decode_step(p, b), jit=jit)


def generate(model: Model, params: dict, prompt: jnp.ndarray, steps: int,
             temperature: float = 0.0, key=None,
             s_max: Optional[int] = None, jit: bool = True) -> jnp.ndarray:
    """Batched generation: prefill the prompt, then decode `steps` tokens.

    The prompt is forwarded ONCE: the prefill that builds the caches also
    yields the last-token logits the first sampled token needs (a second
    full forward over the prompt would double prefill compute for nothing).
    The decode step is jitted (``jit=False`` to debug eagerly).

    prompt: (B, S) int32. Returns (B, S + steps)."""
    b, s = prompt.shape
    s_max = s_max or (s + steps)
    logits_p, caches = prefill_fn(model, jit=jit)(params,
                                                  {"tokens": prompt})
    caches = pad_caches(model, caches, s_max)

    def sample(logits, k):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature, axis=-1
                                      ).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok = sample(logits_p[:, -1], key)[:, None]
    out = [prompt, tok]

    step = decode_fn(model, jit=jit)
    for t in range(steps - 1):
        idx = s + t
        batch = {"tokens": tok,
                 "positions": jnp.full((b, 1), idx, jnp.int32),
                 "cache_index": jnp.full((b,), idx, jnp.int32),
                 "caches": caches}
        logits, caches = step(params, batch)
        key = jax.random.fold_in(key, t)
        tok = sample(logits[:, 0], key)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
