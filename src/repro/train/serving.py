"""Serving utilities: prefill -> decode continuation, cache padding, and a
batched greedy/sampling generation loop (the paper's "inference" side --
adapters stay unmerged, exactly how the paper evaluates QOFT/QLoRA).

Multi-tenant serving (many adapters, one frozen base, mixed batches) lives
in ``repro.serving``; it builds on the same primitives here (``pad_caches``,
the per-model jit cache)."""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.model import Model


def pad_caches(model: Model, caches: dict, s_max: int) -> dict:
    """Grow prefill caches (seq dim == prompt length) to s_max decode slots.

    Attention caches get zero-padded k/v and pos=-1 (invalid) tail; SSM
    states are seq-free and pass through. SWA ring caches (already capped at
    the window) pass through too."""
    cfg = model.cfg

    def pad_entry(p, entry):
        if tfm.layer_kind(cfg, p) != "attn":
            return entry
        cur = entry["k"].shape[2]          # (n_groups, B, S, KV, hd)
        if cur >= s_max or (0 < cfg.sliding_window <= cur):
            return entry
        padn = s_max - cur
        k = jnp.pad(entry["k"], ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(entry["v"], ((0, 0), (0, 0), (0, padn), (0, 0), (0, 0)))
        pos = jnp.pad(entry["pos"], ((0, 0), (0, 0), (0, padn)),
                      constant_values=-1)
        return {"k": k, "v": v, "pos": pos}

    return {key: pad_entry(int(key.split("_")[1]), val)
            for key, val in caches.items()}


def model_jit_fn(model: Model, name: str, fn, jit: bool = True):
    """Per-model-instance jit cache: the compiled fn survives across
    ``generate`` calls (and across the N sequential runs of the serving
    benchmark's baseline) instead of retracing per call.  ``jit=False`` is
    the debugging escape hatch -- the raw fn, eager, with real stack
    traces."""
    if not jit:
        return fn
    cache = getattr(model, "_jit_cache", None)
    if cache is None:
        cache = {}
        model._jit_cache = cache
    if name not in cache:
        cache[name] = jax.jit(fn)
    return cache[name]


def prefill_fn(model: Model, jit: bool = True):
    """(params, batch) -> (logits, caches), jitted per model instance."""
    return model_jit_fn(model, "prefill",
                        lambda p, b: model.prefill(p, b), jit=jit)


def decode_fn(model: Model, jit: bool = True):
    """(params, batch) -> (logits, new_caches), jitted per model instance.
    Per-token dispatch overhead -- not math -- dominates small-model
    decode, so the step is compiled once and reused across all steps,
    generate() calls, and serving-engine ticks."""
    return model_jit_fn(model, "decode",
                        lambda p, b: model.decode_step(p, b), jit=jit)


def generate(model: Model, params: dict, prompt: jnp.ndarray,
             steps: Optional[int] = None, temperature: float = 0.0,
             key=None, s_max: Optional[int] = None, jit: bool = True,
             sampling=None) -> jnp.ndarray:
    """Batched generation: prefill the prompt, then decode.

    Serving API v2 made this a convenience wrapper over a single-adapter
    ``repro.serving.ServingEngine`` (one request per prompt row), so there
    is exactly ONE prefill/decode data plane and ONE sampling
    implementation between ``generate`` and the multi-tenant engine.  The
    prompt is still forwarded once -- the prefill that builds the caches
    also yields the first token's logits.

    Pass ``sampling=repro.serving.SamplingParams(...)``; the legacy
    ``steps=``/``temperature=`` spelling still works but is deprecated.
    With ``sampling.eos_id`` set, rows that stop early are right-padded
    with ``eos_id`` (the legacy spelling never stops early).

    prompt: (B, S) int32. Returns (B, S + max_new_tokens)."""
    from repro.serving.api import Request, SamplingParams
    from repro.serving.engine import ServingEngine

    b, s = prompt.shape
    if sampling is None:
        if steps is None:
            raise TypeError("generate() requires sampling= (or the "
                            "deprecated steps=)")
        warnings.warn(
            "generate(steps=, temperature=) is deprecated; pass "
            "sampling=repro.serving.SamplingParams(max_new_tokens=, "
            "temperature=)", DeprecationWarning, stacklevel=2)
        sampling = SamplingParams(
            max_new_tokens=steps,
            temperature=temperature if temperature > 0 else None)
    elif steps is not None:
        raise TypeError("generate(): pass either sampling= or the "
                        "deprecated steps=, not both")

    engine = ServingEngine(model, params, pool=None, n_slots=b,
                           s_max=s_max or (s + sampling.max_new_tokens),
                           jit=jit, key=key, mode="slots")
    prompt_np = np.asarray(prompt)
    out = engine.run([Request(f"row{i}", prompt_np[i], sampling=sampling)
                      for i in range(b)])
    gen = np.full((b, sampling.max_new_tokens),
                  sampling.eos_id if sampling.eos_id is not None else 0,
                  np.int32)
    for i in range(b):
        toks = out[f"row{i}"]
        gen[i, :len(toks)] = toks
    return jnp.concatenate([jnp.asarray(prompt_np, jnp.int32),
                            jnp.asarray(gen)], axis=1)
