"""Fault-tolerant training loop.

  * auto-resume from the newest VALID checkpoint (params + optimizer + data
    cursor + RNG + step); a corrupt or torn latest step is skipped with a
    warning (checksum fallback in CheckpointManager.restore)
  * periodic async checkpoints (atomic keep-k)
  * SIGTERM/SIGINT preemption -> final checkpoint flush + clean exit
  * straggler monitor on step wall-times
  * optional chaos harness (``chaos=FaultSchedule(...)``): injected
    preemptions / device loss / save crashes / checkpoint corruption /
    straggler delays, all deterministic and replayable
  * works off-mesh (CPU tests/examples) or on-mesh (jit with shardings)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.config.base import RunConfig
from repro.data.loader import ShardedLoader
from repro.distributed.fault import PreemptionGuard, StragglerMonitor
from repro.models.model import Model
from repro.train import state as state_lib
from repro.train.step import make_train_step


def run_training(model: Model, run: RunConfig, loader: ShardedLoader,
                 train_step: Optional[Callable] = None,
                 manager: Optional[CheckpointManager] = None,
                 guard: Optional[PreemptionGuard] = None,
                 log: Callable[[str], None] = print,
                 init_key=None,
                 stop_after: Optional[int] = None,
                 place_state: Optional[Callable] = None,
                 chaos=None,
                 metrics_dir: Optional[str] = None) -> Dict[str, Any]:
    """``place_state`` (on-mesh launches): applied to the TrainState after
    init/restore -- device_put params to their NamedShardings so jit
    in_shardings come from committed placement, not per-step resharding.

    ``chaos`` (optional ``repro.distributed.chaos.FaultSchedule``): fires
    scheduled faults at the top of each step and injects straggler delays
    inside the step-timing window (so the monitor sees them).

    ``metrics_dir`` (optional): telemetry artifacts (metrics.jsonl /
    metrics.prom / spans.jsonl) are dumped there at every checkpoint and
    at exit; the JSONL files append, so a restarted run's telemetry
    stitches across restarts."""
    tc = run.train
    manager = manager or CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep)
    guard = guard or PreemptionGuard(install=False)
    monitor = StragglerMonitor()
    step_fn = train_step or jax.jit(make_train_step(model, run))

    # ---- init or resume -------------------------------------------------
    key = init_key if init_key is not None else jax.random.PRNGKey(tc.seed)
    params = model.init(key)
    state = state_lib.create(
        params, use_compression=(run.parallel.gradient_compression == "int8"))
    start_step = 0
    if manager.latest_step() is not None:
        # step=None -> newest VALID step: a corrupt/torn latest checkpoint
        # is skipped (with a warning) instead of killing the resume
        restored, meta = manager.restore(like=state)
        state = jax.tree_util.tree_map(jax.numpy.asarray, restored)
        if "data_cursor" in meta:
            loader.restore({"cursor": meta["data_cursor"]})
        else:
            log("[loop] checkpoint metadata has no data_cursor "
                "(legacy/foreign checkpoint); data stream restarts at 0")
        if meta.get("rng") is not None:
            key = jax.numpy.asarray(np.asarray(meta["rng"], dtype=np.uint32))
        start_step = int(meta["step"])
        log(f"[loop] resumed from step {start_step} "
            f"(data cursor {meta.get('data_cursor', 0)})")
    if place_state is not None:
        state = place_state(state)

    def dump_metrics():
        if metrics_dir is not None:
            obs.dump(metrics_dir)

    losses = []
    stragglers = 0
    t_loop = time.time()
    for step in range(start_step, tc.steps):
        with obs.span("train.step", step=step):
            if chaos is not None:
                chaos.on_step(step, guard=guard, manager=manager)
            batch = loader.next_batch()
            batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            if chaos is not None:
                delay = chaos.straggler_delay(step)
                if delay > 0:
                    time.sleep(delay)  # inside the timed window, on purpose
            dt = time.time() - t0
        if monitor.record(step, dt):
            stragglers += 1
            log(f"[loop] straggler step {step}: {dt:.3f}s "
                f"(ewma {monitor.ewma:.3f}s)")
        losses.append(loss)
        obs.record_train_step(dt, loss, float(metrics["grad_norm"]),
                              float(metrics["lr"]),
                              int(np.size(batch["tokens"]))
                              if "tokens" in batch else 0)
        if tc.log_every and step % tc.log_every == 0:
            log(f"[loop] step {step} loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
        must_ckpt = (tc.ckpt_every and (step + 1) % tc.ckpt_every == 0)
        if must_ckpt or guard.requested:
            with obs.span("train.checkpoint", step=step + 1):
                manager.save(step + 1, state,
                             metadata={"data_cursor":
                                       loader.checkpoint()["cursor"],
                                       "step": step + 1,
                                       "rng": np.asarray(key).astype(
                                           np.uint32).tolist()})
            dump_metrics()
            if guard.requested:
                manager.wait()
                obs.metric("train/preemptions_total").inc()
                obs.event("train.preempted", step=step + 1)
                log(f"[loop] preempted at step {step + 1}; checkpoint "
                    f"flushed, exiting")
                dump_metrics()
                return {"state": state, "losses": losses,
                        "preempted": True, "last_step": step + 1,
                        "stragglers": stragglers}
        if stop_after is not None and step + 1 >= stop_after:
            manager.wait()
            dump_metrics()
            return {"state": state, "losses": losses, "preempted": False,
                    "last_step": step + 1, "stragglers": stragglers}
    manager.wait()
    dump_metrics()
    return {"state": state, "losses": losses, "preempted": False,
            "last_step": tc.steps, "stragglers": stragglers,
            "wall_time": time.time() - t_loop}
