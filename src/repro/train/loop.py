"""Fault-tolerant training loop.

  * auto-resume from the latest checkpoint (params + optimizer + data cursor
    + RNG + step)
  * periodic async checkpoints (atomic keep-k)
  * SIGTERM preemption -> final checkpoint flush + clean exit
  * straggler monitor on step wall-times
  * works off-mesh (CPU tests/examples) or on-mesh (jit with shardings)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import RunConfig
from repro.data.loader import ShardedLoader
from repro.distributed.fault import PreemptionGuard, StragglerMonitor
from repro.models.model import Model
from repro.train import state as state_lib
from repro.train.step import make_train_step


def run_training(model: Model, run: RunConfig, loader: ShardedLoader,
                 train_step: Optional[Callable] = None,
                 manager: Optional[CheckpointManager] = None,
                 guard: Optional[PreemptionGuard] = None,
                 log: Callable[[str], None] = print,
                 init_key=None,
                 stop_after: Optional[int] = None,
                 place_state: Optional[Callable] = None) -> Dict[str, Any]:
    """``place_state`` (on-mesh launches): applied to the TrainState after
    init/restore -- device_put params to their NamedShardings so jit
    in_shardings come from committed placement, not per-step resharding."""
    tc = run.train
    manager = manager or CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep)
    guard = guard or PreemptionGuard(install=False)
    monitor = StragglerMonitor()
    step_fn = train_step or jax.jit(make_train_step(model, run))

    # ---- init or resume -------------------------------------------------
    key = init_key if init_key is not None else jax.random.PRNGKey(tc.seed)
    params = model.init(key)
    state = state_lib.create(
        params, use_compression=(run.parallel.gradient_compression == "int8"))
    start_step = 0
    latest = manager.latest_step()
    if latest is not None:
        restored, meta = manager.restore(latest, like=state)
        state = jax.tree_util.tree_map(jax.numpy.asarray, restored)
        loader.restore({"cursor": meta["data_cursor"]})
        start_step = int(meta["step"])
        log(f"[loop] resumed from step {start_step} "
            f"(data cursor {meta['data_cursor']})")
    if place_state is not None:
        state = place_state(state)

    losses = []
    stragglers = 0
    t_loop = time.time()
    for step in range(start_step, tc.steps):
        batch = loader.next_batch()
        batch = jax.tree_util.tree_map(jax.numpy.asarray, batch)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if monitor.record(step, dt):
            stragglers += 1
            log(f"[loop] straggler step {step}: {dt:.3f}s "
                f"(ewma {monitor.ewma:.3f}s)")
        losses.append(loss)
        if tc.log_every and step % tc.log_every == 0:
            log(f"[loop] step {step} loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
        must_ckpt = (tc.ckpt_every and (step + 1) % tc.ckpt_every == 0)
        if must_ckpt or guard.requested:
            manager.save(step + 1, state,
                         metadata={"data_cursor": loader.checkpoint()["cursor"],
                                   "step": step + 1})
            if guard.requested:
                manager.wait()
                log(f"[loop] preempted at step {step + 1}; checkpoint "
                    f"flushed, exiting")
                return {"state": state, "losses": losses,
                        "preempted": True, "last_step": step + 1,
                        "stragglers": stragglers}
        if stop_after is not None and step + 1 >= stop_after:
            manager.wait()
            return {"state": state, "losses": losses, "preempted": False,
                    "last_step": step + 1, "stragglers": stragglers}
    manager.wait()
    return {"state": state, "losses": losses, "preempted": False,
            "last_step": tc.steps, "stragglers": stragglers,
            "wall_time": time.time() - t_loop}
