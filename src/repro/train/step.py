"""Train/serve step factories.

train_step: per-step OFT rotation build (hoisted, see below) -> microbatched
(scan) grad accumulation -> optional int8 compression w/ error feedback ->
global-norm clip -> AdamW on the adapter tree only. Base weights are never
differentiated: the PEFT memory story (grads + optimizer state are
O(adapter)) is structural, not an afterthought -- it is what lets a 405B
frozen model train on v5e-256.  The frozen-base assumption also reaches the
kernels: the fused OFTv2/QOFT backward never computes dW (or the rotated-
activation recompute feeding it) -- `core/oft.oftv2_linear` passes
train_w=False so the skip is structural, not an XLA-DCE hope.

Rotation hoisting (core/rotations.py): for OFTv2 the block rotations are
built from the packed skew params ONCE per train step -- one concatenated
Cayley-Neumann build before the microbatch scan -- and threaded to every
adapted linear as `r_blocks` riding in the adapter tree.  Gradients
accumulate w.r.t. the rotations across the scan and are pulled back through
the build's VJP once per step, which is exact (the VJP is linear in the
cotangent).

serve_step_prefill / serve_step_decode: the two inference shapes the
dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RunConfig
from repro.core import rotations as rot_lib
from repro.models.model import Model
from repro.optim import adamw, clipping, schedule
from repro.train import state as state_lib


def _split_microbatches(batch: Dict[str, jnp.ndarray], m: int):
    return jax.tree_util.tree_map(
        lambda a: a.reshape((m, a.shape[0] // m) + a.shape[1:]), batch)


def make_train_step(model: Model, run: RunConfig,
                    hoist_rotations: Optional[bool] = None) -> Callable:
    tc = run.train
    pcfg = run.parallel
    m = max(pcfg.microbatches, 1)
    use_remat = pcfg.remat != "none"
    use_comp = pcfg.gradient_compression == "int8"
    acfg = run.adapter
    # mesh-native path: the model carries a validated MeshContext; the
    # hoisted rotation build constrains its output leaves to their TP
    # layout so the per-shard fused kernels consume them locally.
    shard = model.shard
    if shard is not None and tc.global_batch % max(
            shard.axis_shards(shard.data_axes), 1):
        raise ValueError(
            f"global_batch={tc.global_batch} not divisible by the "
            f"{shard.axis_shards(shard.data_axes)}-way data axes of the "
            f"mesh")

    def loss_fn(adapter, base, mb):
        loss, metrics = model.loss({"base": base, "adapter": adapter}, mb,
                                   remat=use_remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: state_lib.TrainState, batch) -> Tuple:
        # Hoisted rotations: ONE Cayley-Neumann build (and, via the vjp,
        # ONE backward through it) per train step, shared by every adapted
        # linear and every microbatch.  `adapter` below is the augmented
        # tree; its grads are pulled back to packed-skew space after the
        # scan, which is exact -- the build's VJP is linear in dR.
        hoist = rot_lib.should_hoist(state.adapter, acfg) \
            if hoist_rotations is None else hoist_rotations
        if hoist:
            adapter, pullback = jax.vjp(
                lambda a: rot_lib.with_rotations(a, acfg, shard=shard),
                state.adapter)
        else:
            adapter, pullback = state.adapter, None

        if m > 1:
            mbs = _split_microbatches(batch, m)

            def acc_step(carry, mb):
                gsum, lsum = carry
                (loss, _), g = grad_fn(adapter, state.base, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), adapter)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
            loss = lsum / m
        else:
            (loss, _), grads = grad_fn(adapter, state.base, batch)

        if pullback is not None:
            grads = pullback(grads)[0]

        comp_err = state.comp_err
        if use_comp:
            from repro.optim import compression
            grads, comp_err = compression.compress_decompress(grads,
                                                              comp_err)

        grads, gnorm = clipping.clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule.learning_rate(state.step, tc)
        new_adapter, new_opt = adamw.update(grads, state.opt, state.adapter,
                                            lr, tc)
        new_state = state_lib.TrainState(
            step=state.step + 1, base=state.base, adapter=new_adapter,
            opt=new_opt, comp_err=comp_err)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_serve_prefill(model: Model) -> Callable:
    def serve_step(params, batch):
        logits, caches = model.prefill(params, batch)
        return logits, caches
    return serve_step


def make_serve_decode(model: Model) -> Callable:
    def serve_step(params, batch):
        logits, caches = model.decode_step(params, batch)
        return logits, caches
    return serve_step
