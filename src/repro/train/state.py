"""TrainState: base (frozen) + adapter (trainable) params, AdamW state over
the adapter tree only, optional compression error-feedback state."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw


class TrainState(NamedTuple):
    step: jnp.ndarray            # () int32 (global step)
    base: Any                    # frozen (possibly quantized) params
    adapter: Any                 # trainable adapter params
    opt: adamw.AdamWState        # over adapter only
    comp_err: Any                # int8-compression error feedback (or None)


def create(params: Dict[str, Any], use_compression: bool = False
           ) -> TrainState:
    adapter = params["adapter"]
    comp_err = None
    if use_compression:
        from repro.optim import compression
        comp_err = compression.init_error_state(adapter)
    return TrainState(step=jnp.zeros((), jnp.int32), base=params["base"],
                      adapter=adapter, opt=adamw.init(adapter),
                      comp_err=comp_err)


def params_of(state: TrainState) -> Dict[str, Any]:
    return {"base": state.base, "adapter": state.adapter}
