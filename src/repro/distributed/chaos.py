"""Deterministic, seeded fault-injection (chaos) harness -- ISSUE-7.

A :class:`FaultSchedule` is an explicit, replayable list of
:class:`FaultEvent`\\ s (step -> fault kind), either hand-written, parsed
from a CLI spec string (``--chaos "preempt@3,corrupt_latest@5"``), or
drawn from a seed (``FaultSchedule.from_seed``).  It plugs into
``train/loop.run_training(chaos=...)`` and, via the same objects, into the
8-fake-device subprocess harness (``tests/_mesh.run_py``) -- every fault a
test injects is a value, not a race, so recovery can be asserted as
loss-trajectory parity against an uninterrupted run.

Fault classes (one per production failure mode):

  ``preempt``         -- SIGTERM-style maintenance event: trips the
                         PreemptionGuard; the loop flushes a checkpoint
                         and exits cleanly.
  ``device_loss``     -- abrupt accelerator loss: raises
                         :class:`DeviceLost` out of the step; the process
                         "dies" and must restart + auto-resume
                         (``run_with_restarts`` is the supervisor).
  ``straggler``       -- injects ``arg`` seconds of delay INSIDE the
                         step-timing window, so the StragglerMonitor's
                         detection path is exercised, not bypassed.
  ``save_crash``      -- arms the CheckpointManager so its next save dies
                         mid-``save_tree`` (torn tmp dir, never a torn
                         ``step_N``); the failure surfaces as
                         :class:`SaveCrashed` (sync save or the next
                         ``wait()``) and the restart must fall back to the
                         previous valid checkpoint.
  ``corrupt_latest``  -- flips bytes in the newest on-disk checkpoint's
                         arrays file; the checksummed restore path must
                         skip it and fall back to the newest VALID step.

Each event fires exactly once even when the run restarts and replays its
step (the schedule tracks fired events), mirroring real faults: a
preemption consumed is a preemption gone.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

FAULT_KINDS = ("preempt", "device_loss", "straggler", "save_crash",
               "corrupt_latest")


class DeviceLost(RuntimeError):
    """Simulated abrupt accelerator/host loss: the training process is
    gone; a fresh ``run_training`` must restart and auto-resume from the
    newest valid checkpoint."""


class SaveCrashed(RuntimeError):
    """The checkpoint writer was killed mid-``save_tree`` (chaos-injected
    fault point); the tmp directory is torn and the run must restart."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``arg`` is kind-specific: straggler delay in
    seconds (default 0.25), the 0-based fault-point index a ``save_crash``
    kills the writer at, or unused."""
    step: int
    kind: str
    arg: float = -1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {FAULT_KINDS})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


def make_save_killer(kill_at: int) -> Callable[[str], None]:
    """A ``save_tree`` fault hook that raises :class:`SaveCrashed` at the
    ``kill_at``-th fault point (0 = before any byte is written); a
    ``kill_at`` past the last point lets the save complete."""
    count = [0]

    def fault(point: str) -> None:
        if count[0] == kill_at:
            raise SaveCrashed(f"chaos: save killed at point {point!r} "
                              f"(index {kill_at})")
        count[0] += 1

    return fault


def corrupt_checkpoint(directory: str, step: Optional[int] = None,
                       seed: int = 0, n_bytes: int = 64) -> int:
    """Flip ``n_bytes`` bytes in the middle of ``step_<step>``'s arrays
    file (newest step when ``step`` is None).  Returns the corrupted step.
    The checksummed restore path must detect this and fall back."""
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(directory, keep=0, async_save=False)
    step = step if step is not None else mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints to corrupt in {directory}")
    path = os.path.join(mgr.step_path(step), "arrays.npz")
    size = os.path.getsize(path)
    rng = np.random.default_rng(seed)
    with open(path, "r+b") as f:
        # land in the middle of the data, away from the zip end-of-central-
        # directory record, so corruption looks like bit rot, not truncation
        start = max(size // 2 - n_bytes, 0)
        f.seek(start)
        orig = f.read(min(n_bytes, size - start))
        f.seek(start)
        f.write(bytes(b ^ int(m) for b, m in
                      zip(orig, rng.integers(1, 256, len(orig)))))
    return step


class FaultSchedule:
    """An ordered, replayable fault plan over training steps.

    ``on_step(step, guard=, manager=)`` fires every not-yet-fired event
    scheduled at ``step`` (preempt/corrupt/save_crash arm-or-act;
    device_loss raises), and ``straggler_delay(step)`` returns the delay
    to inject inside the step-timing window.  Both mark events fired, so a
    restarted run replaying the same step numbers does not re-suffer
    consumed faults."""

    def __init__(self, events: Sequence[FaultEvent], log=None):
        self.events: List[FaultEvent] = sorted(events,
                                               key=lambda e: (e.step, e.kind))
        self._fired = [False] * len(self.events)
        self.log = log if log is not None else (lambda s: None)

    # ---------------------------------------------------------- construct --
    @classmethod
    def from_seed(cls, seed: int, steps: int,
                  rates: Dict[str, float], log=None,
                  straggler_delay: float = 0.25) -> "FaultSchedule":
        """Draw a schedule: each step independently suffers each fault
        kind with probability ``rates[kind]`` -- fully determined by
        ``seed``, so a chaos run is exactly reproducible."""
        unknown = set(rates) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds in rates: {sorted(unknown)}")
        rng = np.random.default_rng(seed)
        events = []
        for step in range(steps):
            for kind in FAULT_KINDS:
                p = rates.get(kind, 0.0)
                if p > 0 and rng.random() < p:
                    arg = straggler_delay if kind == "straggler" else -1.0
                    events.append(FaultEvent(step, kind, arg))
        return cls(events, log=log)

    @classmethod
    def parse(cls, spec: str, log=None) -> "FaultSchedule":
        """Parse a CLI spec: comma-separated ``kind@step`` or
        ``kind@step:arg`` tokens, e.g.
        ``"preempt@3,straggler@5:0.1,corrupt_latest@7"``."""
        events = []
        for token in (t.strip() for t in spec.split(",") if t.strip()):
            if "@" not in token:
                raise ValueError(
                    f"bad chaos token {token!r} (want kind@step[:arg])")
            kind, _, where = token.partition("@")
            step_s, _, arg_s = where.partition(":")
            arg = float(arg_s) if arg_s else \
                (0.25 if kind == "straggler" else -1.0)
            events.append(FaultEvent(int(step_s), kind, arg))
        return cls(events, log=log)

    # -------------------------------------------------------------- query --
    def __len__(self) -> int:
        return len(self.events)

    def pending(self) -> List[FaultEvent]:
        return [e for e, f in zip(self.events, self._fired) if not f]

    def fired(self) -> List[FaultEvent]:
        return [e for e, f in zip(self.events, self._fired) if f]

    def _take(self, step: int, kinds: Tuple[str, ...]) -> List[FaultEvent]:
        out = []
        for i, e in enumerate(self.events):
            if not self._fired[i] and e.step == step and e.kind in kinds:
                self._fired[i] = True
                out.append(e)
        return out

    # --------------------------------------------------------------- fire --
    def on_step(self, step: int, guard=None, manager=None) -> None:
        """Fire this step's non-straggler events.  Called by the train
        loop at the top of each step, BEFORE the forward."""
        for e in self._take(step, ("preempt", "save_crash",
                                   "corrupt_latest", "device_loss")):
            self.log(f"[chaos] step {step}: injecting {e.kind}")
            obs.metric("chaos/faults_fired_total").labels(kind=e.kind).inc()
            obs.event("chaos.fault", kind=e.kind, step=step)
            if e.kind == "preempt":
                if guard is None:
                    raise ValueError("preempt fault needs a PreemptionGuard")
                guard.trigger()
            elif e.kind == "save_crash":
                if manager is None:
                    raise ValueError("save_crash fault needs a "
                                     "CheckpointManager")
                kill_at = int(e.arg) if e.arg >= 0 else 2
                manager.arm_fault(make_save_killer(kill_at))
            elif e.kind == "corrupt_latest":
                if manager is None:
                    raise ValueError("corrupt_latest fault needs a "
                                     "CheckpointManager")
                if manager.latest_step() is not None:
                    s = corrupt_checkpoint(manager.directory)
                    self.log(f"[chaos] corrupted checkpoint step_{s}")
            elif e.kind == "device_loss":
                raise DeviceLost(f"chaos: device lost at step {step}")

    def straggler_delay(self, step: int) -> float:
        """Seconds of delay to inject inside the step-timing window (0.0
        when no straggler is scheduled at ``step``)."""
        delay = 0.0
        for e in self._take(step, ("straggler",)):
            self.log(f"[chaos] step {step}: straggler +{e.arg:.3f}s")
            obs.metric("chaos/faults_fired_total").labels(kind=e.kind).inc()
            obs.event("chaos.fault", kind=e.kind, step=step, arg=e.arg)
            delay += e.arg if e.arg >= 0 else 0.25
        return delay


def run_with_restarts(attempt: Callable[[], dict],
                      max_restarts: int = 8,
                      log=None) -> Tuple[dict, int]:
    """Supervisor loop: call ``attempt()`` (typically a ``run_training``
    closure) until it completes, restarting on injected
    :class:`DeviceLost` / :class:`SaveCrashed` -- the in-process stand-in
    for a cluster manager rescheduling a killed job.  Returns
    ``(result, n_restarts)``; re-raises after ``max_restarts``."""
    log = log if log is not None else (lambda s: None)
    restarts = 0
    while True:
        try:
            return attempt(), restarts
        except (DeviceLost, SaveCrashed) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            obs.metric("train/restarts_total").inc()
            obs.event("chaos.restart", attempt=restarts, cause=str(e))
            log(f"[chaos] restart {restarts}/{max_restarts} after: {e}")


def main(argv=None):
    """CLI for CI chaos smokes: ``python -m repro.distributed.chaos
    corrupt <ckpt_dir> [step]`` flips bytes in the newest (or given)
    checkpoint, so a follow-up resume must take the fallback path."""
    import sys
    argv = argv if argv is not None else sys.argv[1:]
    if not argv or argv[0] != "corrupt" or len(argv) not in (2, 3):
        raise SystemExit("usage: python -m repro.distributed.chaos "
                         "corrupt <ckpt_dir> [step]")
    step = int(argv[2]) if len(argv) == 3 else None
    s = corrupt_checkpoint(argv[1], step=step)
    print(f"[chaos] corrupted {argv[1]}/step_{s}")


if __name__ == "__main__":
    main()
