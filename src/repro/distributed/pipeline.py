"""GPipe-style pipeline parallelism with shard_map + lax.ppermute.

For >2-pod scaling where per-layer FSDP all-gathers would saturate DCI,
the layer stack is split into S stages sharded over a 'stage' mesh axis;
microbatches stream through with the classic (M + S - 1)-tick schedule.
Forward-only and forward+backward (via jax.vjp through the pipelined
computation -- XLA reverses the ppermutes automatically) both work; the
equivalence test checks gradients against the sequential stack.

This is a first-class runtime feature validated on an 8-device CPU mesh in
tests/test_pipeline.py (subprocess); the 512-chip dry-run uses FSDP+TP
because PEFT has no optimizer-state pressure (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(stage_fn: Callable, mesh: Mesh, stage_axis: str = "stage"):
    """Build a pipelined apply.

    stage_fn(stage_params, x) -> x applies ONE stage's chunk of layers.
    Returns pipelined(params_stacked, x_micro) where
      params_stacked: pytree with leading dim S (sharded over stage_axis)
      x_micro: (M, mb, ...) microbatched input (replicated)
    -> (M, mb, ...) outputs."""
    s = mesh.shape[stage_axis]

    def per_shard(params_local, x_micro):
        # params_local leaves: (1, ...) -- this shard's stage params
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        m = x_micro.shape[0]
        n_ticks = m + s - 1
        mb_shape = x_micro.shape[1:]

        state = jnp.zeros(mb_shape, x_micro.dtype)       # current activation
        outputs = jnp.zeros_like(x_micro)

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (if in range)
            feed = x_micro[jnp.clip(t, 0, m - 1)]
            state = jnp.where(stage_id == 0,
                              jnp.where(t < m, feed, state), state)
            out = stage_fn(params_local, state)
            # last stage emits microbatch t - (S - 1)
            emit_idx = t - (s - 1)
            do_emit = (stage_id == s - 1) & (emit_idx >= 0)
            outputs = jax.lax.cond(
                do_emit,
                lambda o: o.at[jnp.clip(emit_idx, 0, m - 1)].set(out),
                lambda o: o, outputs)
            # shift activations to the next stage
            state = jax.lax.ppermute(
                out, stage_axis,
                perm=[(i, (i + 1) % s) for i in range(s)])
            return (state, outputs), None

        (state, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                           jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outputs = jax.lax.psum(
            jnp.where(stage_id == s - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis)
        return outputs

    pspec = jax.tree_util.tree_map(lambda _: P(stage_axis), 0)

    def pipelined(params_stacked, x_micro):
        in_specs = (jax.tree_util.tree_map(lambda _: P(stage_axis),
                                           params_stacked),
                    P())
        return shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(params_stacked,
                                                         x_micro)

    return pipelined


def split_stages(stacked_params, n_stages: int):
    """Reshape scan-stacked layer params (L, ...) -> (S, L/S, ...) for
    stage sharding; stage_fn then scans its local (L/S, ...) chunk."""
    def reshape(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree_util.tree_map(reshape, stacked_params)
