"""Fault-tolerance utilities for long multi-pod runs.

  * StragglerMonitor -- EWMA of step times; flags slow steps / slow hosts.
    On a real deployment the per-host heartbeat files feed a coordinator
    that evicts persistent stragglers (restart-from-checkpoint on the
    remaining hosts via elastic resharding); here the detection machinery
    is fully implemented and unit-tested, the eviction policy is a hook.
  * Heartbeat -- periodic liveness file (host -> mtime); `stale_hosts`
    implements the detection side.
  * PreemptionGuard -- SIGTERM/SIGINT -> sets a flag the train loop polls to
    flush a final checkpoint and exit cleanly (TPU maintenance events).
"""
from __future__ import annotations

import os
import signal
import time
from typing import Callable, Dict, List, Optional

from repro import obs


class StragglerMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.count = 0
        self.flagged: List[int] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_slow = (self.count > self.warmup
                   and seconds > self.threshold * self.ewma)
        if is_slow:
            self.flagged.append(step)
            obs.metric("train/stragglers_total").inc()
            obs.event("train.straggler", step=step, seconds=seconds,
                      ewma=self.ewma)
        # slow steps should not drag the baseline up
        a = self.alpha if not is_slow else self.alpha * 0.1
        self.ewma = (1 - a) * self.ewma + a * seconds
        return is_slow


class Heartbeat:
    def __init__(self, directory: str, host_id: str):
        self.path = os.path.join(directory, f"heartbeat_{host_id}")
        os.makedirs(directory, exist_ok=True)

    def beat(self) -> None:
        # write-to-temp + os.replace: a concurrent `stale_hosts` read can
        # never observe a truncated/empty file (the old truncate-then-write
        # made a live host read as dead whenever the read landed between
        # the truncate and the write)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(time.time()))
            f.flush()
        os.replace(tmp, self.path)

    @staticmethod
    def stale_hosts(directory: str, timeout: float) -> List[str]:
        now = time.time()
        stale = []
        if not os.path.isdir(directory):
            return stale
        for name in os.listdir(directory):
            if not name.startswith("heartbeat_"):
                continue
            try:
                with open(os.path.join(directory, name)) as f:
                    last = float(f.read().strip() or 0)
            except (OSError, ValueError):
                last = 0.0
            if now - last > timeout:
                stale.append(name[len("heartbeat_"):])
        return stale


class PreemptionGuard:
    """SIGTERM/SIGINT -> ``requested`` flag the train loop polls to flush a
    final checkpoint and exit cleanly (TPU maintenance events, scheduler
    preemptions, operator Ctrl-C).

    Both signals are installed (the docstring always promised SIGINT; now
    it is true), the displaced handlers are remembered, and ``uninstall()``
    restores them exactly -- also available as a context manager::

        with PreemptionGuard() as guard:
            run_training(..., guard=guard)
        # previous handlers are back
    """

    SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            self.install()

    @property
    def installed(self) -> bool:
        return bool(self._prev)

    def install(self) -> None:
        for sig in self.SIGNALS:
            if sig in self._prev:
                continue
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass   # not on main thread (tests)

    def uninstall(self) -> None:
        """Restore every handler this guard displaced."""
        while self._prev:
            sig, prev = self._prev.popitem()
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass   # not on main thread (tests)

    def __enter__(self) -> "PreemptionGuard":
        self.install()
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def _handler(self, signum, frame):
        self.requested = True

    def trigger(self) -> None:      # for tests / chaos injection
        self.requested = True
