"""Sharding glue: logical-axis rules -> NamedShardings, the `constrain`
hook threaded through the model (no-op off-mesh, divisibility-checked
with_sharding_constraint on-mesh), and the mesh-native fused-kernel layer:
``MeshContext`` / ``LinearShard`` describe, per adapted linear, which mesh
axis shards the weight's in-features (``k``), out-features (``n``) and the
token dim (``data``), so adapter methods with the ``shards`` capability
(repro.methods) can run their fused Pallas kernels per-shard inside
``shard_map`` -- dense W, NF4 codes/absmax and the rotation blocks stay
TP-sharded over ``model`` with no resharding; the only collectives in the
fused path are the psums a K-sharded linear needs (forward y, backward
dx/dR).

``make_shard_context`` is the config-time gate: methods without the
capability raise NotImplementedError there (not deep inside a trace), and
OFT block counts that do not divide the model axis raise ValueError before
any device buffer exists.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.spec import AxisRules


def axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def axis_fits(mesh: Mesh, ax, dim: int) -> bool:
    """THE drop-don't-fail divisibility policy, shared by make_constrain,
    fit_spec and the per-method shard_map spec resolution: an axis (or
    axis tuple) may shard a dim only when it divides it and the dim is at
    least one row per shard."""
    if ax is None:
        return False
    size = axis_size(mesh, ax)
    return dim % size == 0 and dim >= size


def make_constrain(rules: AxisRules, mesh: Optional[Mesh]):
    """constrain(x, *logical_axes) -> x with a sharding constraint.

    Axes that do not divide the corresponding dim are dropped (e.g. seq=1 in
    decode, or padded-free head counts) rather than failing."""
    if mesh is None:
        return lambda x, *axes: x

    def constrain(x, *axes):
        spec = []
        for i in range(x.ndim):
            lg = axes[i] if i < len(axes) else None
            mesh_ax = rules.lookup(lg)
            spec.append(mesh_ax if axis_fits(mesh, mesh_ax, x.shape[i])
                        else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))

    return constrain


def named_sharding_tree(spec_tree: Any, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s if s is not None else PartitionSpec()),
        spec_tree, is_leaf=lambda s: isinstance(s, PartitionSpec) or s is None)


def batch_spec(pcfg, ndim: int) -> PartitionSpec:
    """Batch tensors: leading dim over (pod, data)."""
    axes = pcfg.data_axes
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return PartitionSpec(lead, *([None] * (ndim - 1)))


# ---------------------------------------------------------------------------
# Mesh-native fused execution (ISSUE-5)
# ---------------------------------------------------------------------------
def fit_spec(spec: PartitionSpec, shape, mesh: Mesh) -> PartitionSpec:
    """Drop spec entries whose mesh axes do not divide the dim (decode
    batch-1 prefill, padded-free head counts) -- ``axis_fits``, applied to
    explicit placement."""
    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        out.append(ax if axis_fits(mesh, ax, dim) else None)
    return PartitionSpec(*out)


def fit_placed(x, spec: Optional[PartitionSpec], mesh: Mesh):
    """device_put with the divisibility-fitted sharding."""
    spec = spec if spec is not None else PartitionSpec()
    return jax.device_put(
        x, NamedSharding(mesh, fit_spec(spec, x.shape, mesh)))


def fit_tree(tree: Any, spec_tree: Any, mesh: Mesh):
    """device_put a whole tree against a PartitionSpec tree, fitting each
    leaf's spec to its shape."""
    return jax.tree_util.tree_map(
        lambda a, s: fit_placed(a, s, mesh), tree, spec_tree,
        is_leaf=lambda s: isinstance(s, PartitionSpec) or s is None)


@dataclass(frozen=True)
class LinearShard:
    """Static sharding of ONE adapted linear ``y = f(x) @ W`` under a mesh:
    ``data`` shards the token/batch dim of activations, ``k`` shards W's
    in-feature dim (and therefore the rotation-block dim -- block-diagonal
    rotations shard exactly like the weight), ``n`` shards W's out-feature
    dim.  A single ``model`` axis can shard k or n, never both."""
    mesh: Mesh
    data: Any            # mesh axis name / tuple / None
    k: Optional[Any]
    n: Optional[Any]


@dataclass(frozen=True)
class MeshContext:
    """Mesh + axis rules threaded build -> Statics -> adapted_linear so the
    ``shards``-capable adapter methods can wrap their fused kernels in
    per-shard ``shard_map`` calls."""
    mesh: Mesh
    rules: AxisRules

    @property
    def data_axes(self):
        """Mesh axes sharding the batch/token dim (from the 'batch' rule)."""
        return self.rules.lookup("batch")

    def linear(self, name: str) -> LinearShard:
        from repro.models.linears import LINEAR_AXES
        in_axis, out_axis = LINEAR_AXES.get(name, (None, None))
        return LinearShard(self.mesh, self.data_axes,
                           self.rules.lookup(in_axis),
                           self.rules.lookup(out_axis))

    def axis_shards(self, names) -> int:
        return axis_size(self.mesh, names)


def make_shard_context(mesh: Optional[Mesh], rules: AxisRules,
                       run) -> Optional[MeshContext]:
    """Config-time construction + validation of the mesh-native fused path.

    * ``None`` mesh -> ``None`` (single-device: everything stays as-is).
    * A method without the ``shards`` capability raises NotImplementedError
      here, naming the methods that do have it -- exactly like the
      multi-tenant pool gate, a registration-time error instead of a wrong
      silent fall-through.
    * Per-linear divisibility (OFT blocks across the model axis, NF4
      code/absmax tiles per shard, TP out-features) is checked through the
      method's ``check_sharding`` hook, so the sharding rules of a method
      live with the method.
    """
    if mesh is None:
        return None
    from repro import methods
    from repro.core import adapter as ad
    from repro.models.linears import LINEAR_AXES, layer_linear_shapes

    acfg, qcfg, cfg = run.adapter, run.quant, run.model
    method = methods.get(acfg.kind)
    ctx = MeshContext(mesh=mesh, rules=rules)
    if not method.has_params:
        return ctx
    if not method.supports_sharding:
        raise NotImplementedError(
            f"adapter method {acfg.kind!r} does not support mesh-sharded "
            f"execution (no 'shards' capability; methods that do: "
            f"{list(methods.supporting('supports_sharding'))})")
    if acfg.fuse_linear:
        # The shard context is threaded through the dense attention+MLP
        # apply paths only; an adapted SSM (in_proj/out_proj) or MoE
        # linear would run its fused kernel with shard=None -- an opaque
        # pallas_call under GSPMD, the silent replication fallback this
        # gate exists to prevent.  Same restriction (and failure mode) as
        # the multi-tenant serving pool.
        ssm = any(cfg.is_ssm_layer(i) for i in range(cfg.num_layers))
        moe_adapted = cfg.num_experts > 0 and (
            "router" in acfg.targets or acfg.adapt_experts)
        if ssm or moe_adapted:
            raise NotImplementedError(
                "the mesh-native fused path is wired through the dense "
                "attention+MLP linears; SSM and MoE-adapted layers do not "
                "thread the shard context yet -- run them off-mesh or "
                "with fuse_linear=False")
    for name, (d_in, d_out) in layer_linear_shapes(cfg).items():
        if not ad.wants_adapter(name, acfg):
            continue
        sh = ctx.linear(name)
        method.check_sharding(name, d_in, d_out, acfg, qcfg,
                              k_shards=axis_size(mesh, sh.k),
                              n_shards=axis_size(mesh, sh.n))
    return ctx
