"""Sharding glue: logical-axis rules -> NamedShardings, and the `constrain`
hook threaded through the model (no-op off-mesh, divisibility-checked
with_sharding_constraint on-mesh)."""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.spec import AxisRules


def axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def make_constrain(rules: AxisRules, mesh: Optional[Mesh]):
    """constrain(x, *logical_axes) -> x with a sharding constraint.

    Axes that do not divide the corresponding dim are dropped (e.g. seq=1 in
    decode, or padded-free head counts) rather than failing."""
    if mesh is None:
        return lambda x, *axes: x

    def constrain(x, *axes):
        spec = []
        for i in range(x.ndim):
            lg = axes[i] if i < len(axes) else None
            mesh_ax = rules.lookup(lg)
            if mesh_ax is not None and x.shape[i] % axis_size(mesh, mesh_ax) == 0 \
                    and x.shape[i] >= axis_size(mesh, mesh_ax):
                spec.append(mesh_ax)
            else:
                spec.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))

    return constrain


def named_sharding_tree(spec_tree: Any, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s if s is not None else PartitionSpec()),
        spec_tree, is_leaf=lambda s: isinstance(s, PartitionSpec) or s is None)


def batch_spec(pcfg, ndim: int) -> PartitionSpec:
    """Batch tensors: leading dim over (pod, data)."""
    axes = pcfg.data_axes
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return PartitionSpec(lead, *([None] * (ndim - 1)))
