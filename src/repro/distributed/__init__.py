from repro.distributed.fault import Heartbeat, PreemptionGuard, StragglerMonitor
from repro.distributed.sharding import (batch_spec, make_constrain,
                                        named_sharding_tree)

__all__ = ["Heartbeat", "PreemptionGuard", "StragglerMonitor", "batch_spec",
           "make_constrain", "named_sharding_tree"]
